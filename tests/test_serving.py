"""Batching semantics + end-to-end behavior of ``repro.serving``.

The :class:`MicroBatcher` tests run on a fake clock — requests carry
explicit ``t_submit`` stamps and ``poll(now)`` takes explicit time — so the
flush rules (size flush at ``max_batch``, deadline flush at ``max_wait_ms``,
whichever first) are proven deterministically, with no sleeps and no timing
slack.  The server integration tests use a ring-graph shard small enough
that beam search visits every vector, making per-future result routing
checkable against exact brute force.
"""

import asyncio

import numpy as np
import pytest

from repro.search import ShardTopology
from repro.search.types import SearchStats
from repro.serving import (AdaptiveWindow, AnnServer, FixedWindow,
                           MicroBatcher, PendingRequest, RequestQueue,
                           ServerOverloadedError, ServerStats, ServingConfig)


def _req(t_submit: float, future=None) -> PendingRequest:
    return PendingRequest(query=None, future=future, t_submit=t_submit)


@pytest.fixture(scope="module")
def ring():
    """One 40-vector shard with a ring graph: width 64 > n, so beam search
    visits everything and results are exactly brute force."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(40, 8)).astype(np.float32)
    g = np.stack([(np.arange(40) + s) % 40 for s in range(1, 6)],
                 axis=1).astype(np.int32)
    topo = ShardTopology(data=data,
                         shard_ids=[np.arange(40, dtype=np.int64)],
                         shard_graphs=[g])
    return data, topo


# ---- (a) size flush ------------------------------------------------------

def test_flush_at_max_batch():
    mb = MicroBatcher(max_batch=4, max_wait_s=1e9)  # deadline never trips
    reqs = [_req(float(i)) for i in range(9)]
    for r in reqs[:3]:
        assert mb.add(r) is None
    batch = mb.add(reqs[3])
    assert batch == reqs[:4]  # oldest first, exactly max_batch
    assert len(mb) == 0
    # the next four fill a fresh batch
    for r in reqs[4:7]:
        assert mb.add(r) is None
    assert mb.add(reqs[7]) == reqs[4:8]
    assert mb.add(reqs[8]) is None  # a 9th starts batch three


# ---- (b) deadline flush --------------------------------------------------

def test_flush_at_max_wait():
    mb = MicroBatcher(max_batch=100, max_wait_s=0.005)
    a, b = _req(0.0), _req(0.003)
    assert mb.add(a) is None and mb.add(b) is None
    # window counts from the *oldest* pending request
    assert mb.deadline() == pytest.approx(0.005)
    assert mb.poll(0.00499) is None
    assert mb.poll(0.005) == [a, b]
    assert len(mb) == 0 and mb.poll(1.0) is None  # empty: nothing to flush
    # the next request opens a new window from its own submit time
    c = _req(0.010)
    mb.add(c)
    assert mb.deadline() == pytest.approx(0.015)
    assert mb.poll(0.014) is None
    assert mb.poll(0.015) == [c]


def test_window_retune_moves_open_deadline():
    """An SLOPolicy retunes ``max_wait_s`` mid-batch; the derived deadline
    must follow (depth spikes should flush an already-open batch early)."""
    mb = MicroBatcher(max_batch=100, max_wait_s=0.050)
    mb.add(_req(0.0))
    assert mb.poll(0.010) is None  # 50 ms window still open
    mb.max_wait_s = 0.002  # policy collapsed the window
    assert mb.poll(0.010) is not None  # 10 ms > 2 ms → flush now


def test_adaptive_window_policy():
    p = AdaptiveWindow(max_wait_ms=10.0, max_batch=10, min_wait_ms=0.5)
    assert p.window_ms(0) == pytest.approx(10.0)
    assert p.window_ms(5) == pytest.approx(5.0)
    assert p.window_ms(10) == pytest.approx(0.5)  # floor, not 0
    assert p.window_ms(1000) == pytest.approx(0.5)
    assert FixedWindow(3.0).window_ms(1000) == pytest.approx(3.0)


# ---- (c) results route to the right futures ------------------------------

def test_results_route_to_correct_future(ring):
    """Interleaved submit order; every future must resolve to *its own*
    query's exact top-k, not its batch-neighbor's."""
    data, topo = ring
    d2 = ((data[:, None, :] - data[None, :, :]) ** 2).sum(-1)

    async def main():
        sc = ServingConfig(backend="numpy", k=5, width=64, max_batch=4,
                           max_wait_ms=50.0)
        async with AnnServer(topo, config=sc) as srv:
            order = np.random.default_rng(3).permutation(len(data))
            futs = {int(i): srv.submit_nowait(data[i]) for i in order}
            for i, f in futs.items():
                res = await f
                expect = np.argsort(d2[i], kind="stable")[:5]
                assert res.ids[0] == i  # own vector is the 1-NN
                assert set(res.ids.tolist()) == set(expect.tolist()), i
                assert res.latency_s >= 0.0
                assert 1 <= res.batch_size <= 4
        assert srv.stats.n_completed == len(data)
        occ = srv.stats.occupancy()
        assert occ["max"] <= 4

    asyncio.run(main())


# ---- (d) bounded-queue admission -----------------------------------------

def test_bounded_queue_rejection():
    async def main():
        loop = asyncio.get_running_loop()
        q = RequestQueue(MicroBatcher(100, 1e9), max_pending=3,
                         admission="reject")
        reqs = [_req(0.0, loop.create_future()) for _ in range(4)]
        for r in reqs[:3]:
            assert q.submit(r) is None
        with pytest.raises(ServerOverloadedError, match="full"):
            q.submit(reqs[3])
        assert q.depth() == 3  # the rejected request was never admitted

    asyncio.run(main())


def test_bounded_queue_shed_oldest():
    async def main():
        loop = asyncio.get_running_loop()
        q = RequestQueue(MicroBatcher(100, 1e9), max_pending=3,
                         admission="shed")
        reqs = [_req(float(i), loop.create_future()) for i in range(5)]
        for r in reqs[:3]:
            q.submit(r)
        assert q.submit(reqs[3]) is reqs[0]  # oldest made room
        assert q.submit(reqs[4]) is reqs[1]
        for old in reqs[:2]:
            with pytest.raises(ServerOverloadedError, match="shed"):
                old.future.result()
        assert q.depth() == 3
        # the survivors drain in order on close
        q.close()
        assert await q.next_batch() == reqs[2:5]
        assert await q.next_batch() is None

    asyncio.run(main())


def test_server_reject_surfaces_to_submitter(ring):
    data, topo = ring

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=4,
                           max_wait_ms=200.0, max_pending=4,
                           admission="reject")
        async with AnnServer(topo, config=sc) as srv:
            futs = []
            rejected = 0
            for i in range(12):
                try:
                    futs.append(srv.submit_nowait(data[i]))
                except ServerOverloadedError:
                    rejected += 1
            assert rejected > 0
            outs = await asyncio.gather(*futs)
            assert len(outs) + rejected == 12
        assert srv.stats.n_rejected == rejected
        assert srv.stats.n_completed == len(futs)

    asyncio.run(main())


# ---- queue drain / shutdown ----------------------------------------------

def test_close_drains_pending():
    async def main():
        loop = asyncio.get_running_loop()
        q = RequestQueue(MicroBatcher(3, 1e9), max_pending=100)
        reqs = [_req(float(i), loop.create_future()) for i in range(5)]
        for r in reqs:
            q.submit(r)
        q.close()
        with pytest.raises(RuntimeError, match="clos"):
            q.submit(_req(9.0, loop.create_future()))
        # one size-flushed batch already waiting, then the remainder
        assert await q.next_batch() == reqs[:3]
        assert await q.next_batch() == reqs[3:]
        assert await q.next_batch() is None

    asyncio.run(main())


def test_server_stop_answers_everything(ring):
    """`async with` exit must serve every admitted request, not drop them."""
    data, topo = ring

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=64,
                           max_wait_ms=10_000.0)  # would wait 10 s...
        async with AnnServer(topo, config=sc) as srv:
            futs = [srv.submit_nowait(data[i]) for i in range(6)]
        # ...but exiting the context drained immediately
        outs = [f.result() for f in futs]
        assert all(o.ids[0] == i for i, o in enumerate(outs))

    asyncio.run(main())


# ---- validation + telemetry ----------------------------------------------

def test_submit_validation(ring):
    data, topo = ring

    async def main():
        async with AnnServer(topo, config=ServingConfig(
                backend="numpy", k=3, width=16)) as srv:
            with pytest.raises(ValueError, match="vector"):
                srv.submit_nowait(np.zeros((3, 8), np.float32))
            with pytest.raises(ValueError, match="vector"):
                srv.submit_nowait(np.zeros(7, np.float32))
            with pytest.raises(ValueError, match="nprobe"):
                srv.submit_nowait(data[0], nprobe="always")

    asyncio.run(main())


def test_submit_before_start_raises(ring):
    _, topo = ring
    srv = AnnServer(topo, config=ServingConfig(backend="numpy"))
    with pytest.raises(RuntimeError, match="not started"):
        srv.submit_nowait(np.zeros(8, np.float32))


def test_bad_config_fails_at_construction(ring):
    _, topo = ring
    with pytest.raises(ValueError, match="backend"):
        AnnServer(topo, config=ServingConfig(backend="cuda"))
    with pytest.raises(ValueError, match="nprobe"):
        AnnServer(topo, config=ServingConfig(backend="numpy", nprobe=0))
    with pytest.raises(ValueError, match="width"):
        AnnServer(topo, config=ServingConfig(backend="numpy", k=10,
                                             width=4))


def test_worker_death_fails_futures_not_hangs(ring):
    """If the worker dies outside the per-batch guard (here: pretrace
    explodes at startup), every admitted future must fail promptly — a
    hung await would be strictly worse — and later submits must say the
    worker is gone."""
    data, topo = ring

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=64,
                           max_wait_ms=5.0, pretrace=True)
        srv = AnnServer(topo, config=sc)

        def boom():
            raise RuntimeError("pretrace exploded")

        srv._pretrace = boom
        srv.start()
        task = srv._worker_task
        fut = srv.submit_nowait(data[0])
        with pytest.raises(RuntimeError, match="exploded"):
            await fut
        await asyncio.wait({task})  # let the task finish unwinding
        with pytest.raises(RuntimeError, match="no longer running"):
            srv.submit_nowait(data[1])
        with pytest.raises(RuntimeError, match="exploded"):
            await srv.stop()
        assert srv.stats.n_failed >= 1

    asyncio.run(main())


def test_engine_error_fails_batch_but_server_survives(ring):
    """An engine failure is scoped to its batch: those futures get the
    exception, and the server keeps serving later requests."""
    data, topo = ring

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=64,
                           max_wait_ms=5.0, pretrace=False)
        async with AnnServer(topo, config=sc) as srv:
            real = srv._execute
            srv._execute = None  # the next batch blows up in the worker
            fut = srv.submit_nowait(data[0])
            with pytest.raises(TypeError):
                await fut
            srv._execute = real  # engine recovers
            res = await srv.submit(data[1])
            assert res.ids[0] == 1
        assert srv.stats.n_failed == 1
        assert srv.stats.n_completed == 1

    asyncio.run(main())


def test_shed_victim_is_globally_oldest():
    """With size-flushed batches waiting in _ready, shedding must evict
    the globally oldest request (in _ready), not the open batch's."""
    async def main():
        loop = asyncio.get_running_loop()
        q = RequestQueue(MicroBatcher(2, 1e9), max_pending=3,
                         admission="shed")
        reqs = [_req(float(i), loop.create_future()) for i in range(4)]
        for r in reqs[:3]:  # 0,1 size-flush into _ready; 2 stays open
            q.submit(r)
        assert q.submit(reqs[3]) is reqs[0]
        with pytest.raises(ServerOverloadedError):
            reqs[0].future.result()
        assert not reqs[2].future.done()  # the open batch was untouched

    asyncio.run(main())


def test_equivalent_nprobe_specs_share_one_engine_call(ring):
    """Spec forms that parse identically ("auto" vs the explicit default
    tuple, int vs np.int64) must not split a flushed batch."""
    data, topo = ring
    from repro.search import DEFAULT_AUTO_MARGIN

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=4,
                           max_wait_ms=50.0)
        async with AnnServer(topo, config=sc) as srv:
            outs = await asyncio.gather(
                srv.submit(data[0], nprobe="auto"),
                srv.submit(data[1], nprobe=("auto", DEFAULT_AUTO_MARGIN)),
                srv.submit(data[2], nprobe=2),
                srv.submit(data[3], nprobe=np.int64(2)),
            )
        assert [o.ids[0] for o in outs] == [0, 1, 2, 3]
        # one flush, two parsed option groups, not four
        assert srv.stats.n_batches == 2
        # batch_size reports the engine call's occupancy, not the flush's
        assert [o.batch_size for o in outs] == [2, 2, 2, 2]

    asyncio.run(main())


def test_mixed_dtype_batches_group_without_cross_contamination(
        ring, monkeypatch):
    """One flush of mixed per-request ``(nprobe, dtype)`` traffic must make
    exactly one engine call per distinct option pair (no splitting of
    equivalent specs, no merging of different ones), route every result to
    its *own* future, and keep jit pre-tracing to the config-default path
    — extra dtypes must not add startup trace buckets.

    Runs on a fake clock: submit stamps are explicit and the server's
    clock is advanced by hand, so the recorded latencies are exact
    arithmetic, not wall-time."""
    data, topo = ring
    calls = []
    import repro.serving.server as srv_mod

    real_search = srv_mod.search

    def recording_search(t, queries, k, **kw):
        calls.append((len(queries), kw.get("nprobe"), kw.get("dtype")))
        return real_search(t, queries, k, **kw)

    monkeypatch.setattr(srv_mod, "search", recording_search)
    now = {"t": 0.0}

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=8,
                           max_wait_ms=50.0, pretrace=True)
        async with AnnServer(topo, config=sc,
                             clock=lambda: now["t"]) as srv:
            combos = [(None, "f32"), (None, "uint8"), (None, "bf16"),
                      (1, "uint8")]
            futs = []
            for i in range(8):  # fills max_batch: one size-flush
                nprobe, dtype = combos[i % 4]
                futs.append(srv.submit_nowait(
                    data[i], nprobe=nprobe, dtype=dtype,
                    t_submit=i * 0.001))
            now["t"] = 1.0  # completions are stamped by the fake clock
            outs = await asyncio.gather(*futs)
        # --- no cross-contamination: each future got its own query's NN
        assert [int(o.ids[0]) for o in outs] == list(range(8))
        # each engine call saw only its group's 2 requests
        assert [o.batch_size for o in outs] == [2] * 8
        # fake-clock latency: exactly (1.0 - submit stamp)
        for i, o in enumerate(outs):
            assert o.latency_s == pytest.approx(1.0 - i * 0.001)
        # --- pre-trace warmed only the default (nprobe, dtype) path, one
        # call per power-of-two bucket (no extra buckets for overrides)
        pre = calls[: len(calls) - 4]
        assert sorted(size for size, _, _ in pre) == [1, 2, 4, 8]
        assert all(np is None and dt == "f32" for _, np, dt in pre)
        # --- the flush split into exactly one call per distinct pair
        flush = calls[-4:]
        assert sorted((str(np), dt) for _, np, dt in flush) == [
            ("1", "uint8"), ("None", "bf16"), ("None", "f32"),
            ("None", "uint8"),
        ]
        assert all(size == 2 for size, _, _ in flush)
        assert srv.stats.n_batches == 4
        snap = srv.stats.snapshot()
        # engine telemetry splits quantized vs re-rank work (f32-only
        # traffic would report 0 for both)
        assert snap["quantized_distance_computations_per_query"] > 0
        assert snap["rerank_distance_computations_per_query"] > 0

    asyncio.run(main())


def test_per_request_dtype_validation(ring):
    data, topo = ring

    async def main():
        async with AnnServer(topo, config=ServingConfig(
                backend="numpy", k=3, width=16)) as srv:
            with pytest.raises(ValueError, match="dtype"):
                srv.submit_nowait(data[0], dtype="fp4")

    asyncio.run(main())
    with pytest.raises(ValueError, match="dtype"):
        AnnServer(topo, config=ServingConfig(backend="numpy",
                                             dtype="int4"))


def test_cancellation_fails_inflight_batch(ring):
    """A worker cancelled mid-engine-call must fail the popped batch's
    futures (fail_all can't see them — they left the queue already)."""
    data, topo = ring

    async def main():
        sc = ServingConfig(backend="numpy", k=3, width=16, max_batch=2,
                           max_wait_ms=1.0, pretrace=False)
        srv = AnnServer(topo, config=sc)
        srv.start()
        import time as _time
        real = srv._execute
        srv._execute = lambda batch: (_time.sleep(0.2), real(batch))[1]
        f1 = srv.submit_nowait(data[0])
        f2 = srv.submit_nowait(data[1])  # size-flush: batch goes in-flight
        await asyncio.sleep(0.05)  # worker is now inside the executor call
        task = srv._worker_task
        task.cancel()
        await asyncio.wait({task})
        for f in (f1, f2):
            assert f.done()
            with pytest.raises(asyncio.CancelledError):
                f.result()

    asyncio.run(main())


def test_bucket_batch_size_is_pow2_capped():
    """The worker's engine-call shapes: powers of two, capped at
    max_batch, so a server traces at most log2(max_batch)+1 jit shapes."""
    from repro.serving.server import bucket_batch_size

    got = [bucket_batch_size(m, 64) for m in (1, 2, 3, 4, 5, 8, 9, 33, 64)]
    assert got == [1, 2, 4, 4, 8, 8, 16, 64, 64]
    assert bucket_batch_size(40, 32) == 32  # never exceeds max_batch


def test_server_stats_accounting():
    st = ServerStats()
    for ms in (1.0, 2.0, 3.0, 4.0, 100.0):
        st.record_completion(0.0, ms / 1e3)
    lat = st.latency_ms()
    assert lat["p50"] == pytest.approx(3.0)
    assert lat["max"] == pytest.approx(100.0)
    assert st.qps() == pytest.approx(5 / 0.1)
    # padding-scaled engine accounting: 8 lanes served 3 real requests
    st.observe_batch(3, 8, SearchStats(n_distance_computations=800,
                                       n_hops=80, n_queries=8), 0.01)
    assert st.dist_comps == pytest.approx(300.0)
    assert st.hops == pytest.approx(30.0)
    snap = st.snapshot()
    assert snap["padding_fraction"] == pytest.approx(5 / 8)
    assert snap["batch_occupancy"]["histogram"] == {"3": 1}


def test_serve_vs_serving_namespaces():
    """`repro.serve` is LM decode; `repro.serving` is ANN.  Neither leaks
    the other's surface (the naming-collision satellite)."""
    import repro.serve as lm
    import repro.serving as ann

    assert "LM decode" in lm.__doc__ and "repro.serving" in lm.__doc__
    assert "ANN" in ann.__doc__ and "repro.serve" in ann.__doc__
    assert not any("Ann" in n or "Search" in n for n in lm.__all__)
    assert "ServeEngine" not in ann.__all__
    assert set(lm.__all__).isdisjoint(ann.__all__)
