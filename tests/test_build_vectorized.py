"""Build-path vectorization parity (PR 5).

Three contracts, one per vectorized hot loop:

  * CAGRA ``optimize_graph`` — the segment-scatter reverse fill and the
    sort-based row dedup are **bit-identical** to the per-node loop
    reference (ids *and* order), on random and clustered fixtures,
    including degenerate shapes (R//2 == 0, L < R).
  * Batched Vamana — same recall@10 as the sequential build within 0.01 at
    a comparable distance budget, on both engine backends; the vectorized
    RobustPrune equals the sequential prune row for row.
  * Merge — the global (gid, neighbor) segment sort preserves the
    permutation-invariance contract and matches the loop reference's id
    sets exactly (bit-identical rows when no distance cap applies).
"""

import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core import builder, cagra, vamana
from repro.core.merge import merge_shard_indexes
from repro.core.partition import Shard, partition
from repro.data.synthetic import (exact_ground_truth, make_clustered,
                                  recall_at)
from repro.search import beam_pool, search


@pytest.fixture(scope="module")
def ds():
    return make_clustered(900, 24, n_queries=40, spread=1.0, seed=13)


@pytest.fixture(scope="module")
def cfg():
    return IndexConfig(n_clusters=3, degree=16, build_degree=32,
                       block_size=512)


# ---------------------------------------------------------------------------
# CAGRA optimize_graph: bit-identity with the loop reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n,d,R,L", [
    (0, 400, 16, 8, 16),
    (1, 800, 32, 16, 32),
    (2, 250, 8, 7, 12),   # odd R: R//2 reverse slots != forward slots
    (3, 120, 4, 12, 6),   # degenerate L < R: dedup must pad, not crash
    (4, 50, 4, 1, 4),     # R == 1: no reverse slots at all (R//2 == 0)
])
def test_optimize_graph_bit_identical_random(seed, n, d, R, L):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    nbrs, dists, _ = cagra.build_knn_graph(x, L)
    g_ref, nd_ref = cagra.optimize_graph(x, nbrs, dists, R, reference=True)
    g_vec, nd_vec = cagra.optimize_graph(x, nbrs, dists, R)
    np.testing.assert_array_equal(g_ref, g_vec)
    assert nd_ref == nd_vec


def test_optimize_graph_bit_identical_clustered(ds, cfg):
    nbrs, dists, _ = cagra.build_knn_graph(ds.data, cfg.build_degree)
    g_ref, _ = cagra.optimize_graph(ds.data, nbrs, dists, cfg.degree,
                                    reference=True)
    g_vec, _ = cagra.optimize_graph(ds.data, nbrs, dists, cfg.degree)
    np.testing.assert_array_equal(g_ref, g_vec)


def test_build_shard_index_reference_flag(ds, cfg):
    """The builder-facing entry points agree bit for bit too."""
    vecs = ds.data[:300]
    a = cagra.build_shard_index(vecs, cfg)
    b = cagra.build_shard_index(vecs, cfg, reference=True)
    np.testing.assert_array_equal(a.graph, b.graph)
    assert a.n_distance_computations == b.n_distance_computations


# ---------------------------------------------------------------------------
# Vamana: batched rounds vs sequential reference
# ---------------------------------------------------------------------------


def test_robust_prune_batch_matches_sequential(ds):
    """Row-for-row exactness of the vectorized prune: same kept ids, same
    order, same distance counting as the per-point reference."""
    rng = np.random.default_rng(5)
    data = ds.data
    for alpha in (1.0, 1.2):
        p_ids = rng.choice(len(data), size=16, replace=False)
        cand = rng.choice(len(data), size=(16, 24))
        # inject self-candidates and padding like a real pool
        cand[:, 3] = p_ids
        cand[:, 20:] = -1
        cand_d = np.where(
            cand >= 0,
            ((data[np.maximum(cand, 0)]
              - data[p_ids][:, None, :]) ** 2).sum(-1),
            np.inf,
        ).astype(np.float32)
        c_batch = [0]
        got = vamana.robust_prune_batch(
            p_ids, cand, cand_d, data, alpha, 8, c_batch
        )
        c_seq = [0]
        for b, p in enumerate(p_ids):
            valid = cand[b] >= 0
            want = vamana.robust_prune(
                int(p), cand[b][valid], cand_d[b][valid], data, alpha, 8,
                c_seq,
            )
            row = got[b]
            np.testing.assert_array_equal(row[row >= 0], want)
        assert c_batch[0] == c_seq[0]


@pytest.mark.parametrize("backend", ["jax", "numpy"])
def test_batched_vamana_recall_parity(ds, cfg, backend):
    """Recall@10 within 0.01 of the sequential build when both indexes are
    searched with the same budget, and the batched build does not spend a
    larger distance budget than the sequential one to get there."""
    vecs = ds.data[:700]
    gt = exact_ground_truth(vecs, ds.queries, 10)
    seq = vamana.build_shard_index_vamana_sequential(vecs, cfg)
    bat = vamana.build_shard_index_vamana(vecs, cfg, backend=backend)
    assert (bat.n_distance_computations
            <= 1.25 * seq.n_distance_computations)

    from repro.core.merge import GlobalIndex

    recalls = {}
    for name, idx in (("seq", seq), ("batched", bat)):
        gi = GlobalIndex(graph=idx.graph, medoid=0, n_vectors=len(vecs))
        ids, _ = search(gi, ds.queries, 10, data=vecs, width=64)
        recalls[name] = recall_at(ids, gt, 10)
    assert recalls["batched"] >= recalls["seq"] - 0.01, recalls


def test_batched_vamana_pad_to_is_inert(ds, cfg):
    """Row padding exists purely for jit-shape sharing: padded and unpadded
    builds produce the same graph."""
    vecs = ds.data[:300]
    a = vamana.build_shard_index_vamana(vecs, cfg, backend="numpy")
    b = vamana.build_shard_index_vamana(vecs, cfg, backend="numpy",
                                        pad_to=512)
    np.testing.assert_array_equal(a.graph, b.graph)
    assert a.n_distance_computations == b.n_distance_computations


def test_beam_pool_matches_search_topk(ds, cfg):
    """The build-time pool's best-k prefix agrees with the serving path on
    the same graph (same engine, same beam) for the numpy reference."""
    vecs = ds.data[:300]
    idx = cagra.build_shard_index(vecs, cfg)
    from repro.core.merge import GlobalIndex

    gi = GlobalIndex(graph=idx.graph, medoid=0, n_vectors=len(vecs))
    q = ds.queries[:8]
    pool_ids, pool_d, stats = beam_pool(
        vecs, idx.graph, 0, q, 32, backend="numpy"
    )
    assert pool_ids.shape == (8, 32) and pool_d.shape == (8, 32)
    assert stats.n_queries == 8
    assert stats.n_distance_computations > 0
    ids, _ = search(gi, q, 10, data=vecs, width=32, n_entries=1)
    # the pool is sorted ascending; its head must be the serving top-k
    np.testing.assert_array_equal(np.sort(pool_ids[:, :10]), np.sort(ids))
    # distances are true squared-L2 values, reusable by RobustPrune
    d_true = ((vecs[np.maximum(pool_ids, 0)] - q[:, None, :]) ** 2).sum(-1)
    valid = pool_ids >= 0
    np.testing.assert_allclose(pool_d[valid], d_true[valid], rtol=1e-4)


# ---------------------------------------------------------------------------
# Merge: segment sort vs loop reference
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def merge_inputs(ds, cfg):
    part = partition(ds.data, cfg)
    idxs = [cagra.build_shard_index(ds.data[s.ids], cfg)
            for s in part.shards]
    return part, idxs


def test_merge_matches_loop_reference(ds, cfg, merge_inputs):
    part, idxs = merge_inputs
    for data in (ds.data, None):
        ref = merge_shard_indexes(part.shards, idxs, len(ds.data),
                                  cfg.degree, data=data, reference=True)
        vec = merge_shard_indexes(part.shards, idxs, len(ds.data),
                                  cfg.degree, data=data)
        assert ref.medoid == vec.medoid
        if data is None:
            # no distance cap: first-seen order, bit-identical
            np.testing.assert_array_equal(ref.graph, vec.graph)
        else:
            # distance-capped: same id set per row (under-capacity rows
            # order by distance instead of first-seen — documented)
            for a, b in zip(ref.graph, vec.graph):
                assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())


def test_merge_segment_sort_is_permutation_invariant(ds, cfg, merge_inputs):
    """§V-C contract against the *new* implementation: permuting rows
    within every shard leaves the merged edge sets unchanged."""
    part, idxs = merge_inputs
    merged = merge_shard_indexes(part.shards, idxs, len(ds.data),
                                 cfg.degree, data=ds.data)
    rng = np.random.default_rng(3)
    pshards, pidxs = [], []
    for s, ix in zip(part.shards, idxs):
        perm = rng.permutation(len(s.ids))
        inv = np.argsort(perm)
        g = ix.graph[perm]
        g = np.where(g >= 0, inv[np.maximum(g, 0)], -1)
        pshards.append(Shard(ids=s.ids[perm], is_replica=s.is_replica[perm]))
        pidxs.append(cagra.ShardIndex(graph=g.astype(np.int32),
                                      n_distance_computations=0))
    merged_p = merge_shard_indexes(pshards, pidxs, len(ds.data), cfg.degree,
                                   data=ds.data)
    for a, b in zip(merged.graph, merged_p.graph):
        assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())


def test_reference_build_flag_end_to_end(ds, cfg):
    """build_scalegann(reference=True) wires the seed-loop paths and still
    produces an index of the same search quality class."""
    sub = ds.data[:500]
    gt = exact_ground_truth(sub, ds.queries, 10)
    ref = builder.build_scalegann(sub, cfg, algo="cagra", reference=True)
    vec = builder.build_scalegann(sub, cfg, algo="cagra")
    # cagra shard builds are bit-identical across the flag; the merged
    # rows carry the same edge sets (under-capacity rows may order by
    # distance instead of first-seen — the documented difference)
    for a, b in zip(ref.shard_graphs, vec.shard_graphs):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(ref.index.graph, vec.index.graph):
        assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())
    ids, _ = search(vec.index, ds.queries, 10, data=sub, width=64)
    assert recall_at(ids, gt, 10) > 0.8
