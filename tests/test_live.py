"""The live mutation layer: recall under churn, tombstone masking,
copy-on-write generations, epoch-swapped serving, and the satellite
regressions (degenerate shard builds, exact entry-point counts).

The churn tests follow the acceptance claim's shape: apply a seeded
insert/delete schedule through :class:`repro.live.LiveIndex` and compare
the mutated index's recall@10 against a *fresh offline rebuild of the same
final point set* — the live graph is allowed to differ structurally, but
not to cost recall.  Tombstone tests assert the hard invariant (a deleted
id is never returned) across all three engine backends, since all of them
flow through the shared drivers that do the masking.
"""

import asyncio
import dataclasses

import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core.builder import build_scalegann
from repro.core.merge import GlobalIndex
from repro.core.vamana import (build_shard_index_vamana,
                               build_shard_index_vamana_sequential)
from repro.data.synthetic import exact_ground_truth, make_clustered, recall_at
from repro.live import LiveConfig, LiveIndex
from repro.search import search
from repro.serving import AnnServer, ServingConfig

CFG = IndexConfig(degree=16, build_degree=32, n_clusters=4)


@pytest.fixture(scope="module")
def churned():
    """One seeded churn run shared by the recall/masking tests: build on
    600 points, insert 120 more, delete 60 (mixing originals and fresh
    inserts), consolidate half-way.  Returns the live index, the deleted
    id set, and the dataset."""
    rng = np.random.default_rng(11)
    ds = make_clustered(720, 16, n_queries=48, gt_k=10, seed=3)
    li = LiveIndex.from_build(
        build_scalegann(ds.data[:600], CFG, algo="vamana"),
        ds.data[:600], CFG, LiveConfig(backend="numpy"),
    )
    li.insert_batch(ds.data[600:])  # global ids line up with dataset rows
    deleted = np.concatenate([
        rng.choice(600, 40, replace=False),
        600 + rng.choice(120, 20, replace=False),
    ])
    li.delete_batch(deleted[:30])
    li.consolidate()  # first wave goes physical
    li.delete_batch(deleted[30:])  # second wave stays tombstoned
    return li, set(int(i) for i in deleted), ds


def _live_gt(li, deleted, queries, k=10):
    live = np.asarray(
        sorted(set(range(li.n_vectors)) - deleted), np.int64
    )
    return live[exact_ground_truth(li._data[live], queries, k)]


def test_insert_parity_vs_offline_rebuild(churned):
    """recall@10 of the churned live index stays within 0.02 of a fresh
    offline build of the same final point set (the acceptance claim)."""
    li, deleted, ds = churned
    gt = _live_gt(li, deleted, ds.queries)
    ids_live, _ = search(li.snapshot(), ds.queries, 10, width=64,
                         backend="numpy", nprobe=3)
    live = np.asarray(sorted(set(range(li.n_vectors)) - deleted), np.int64)
    rebuilt = build_scalegann(li._data[live], CFG, algo="vamana")
    ids_re, _ = search(rebuilt.shard_topology(li._data[live]), ds.queries,
                       10, width=64, backend="numpy", nprobe=3)
    r_live = recall_at(ids_live, gt, 10)
    r_re = recall_at(live[ids_re], gt, 10)
    assert r_live >= r_re - 0.02, (r_live, r_re)


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("dtype", ["f32", "uint8"])
def test_tombstoned_never_returned(churned, backend, dtype):
    """The hard delete invariant, on every backend and distance stage:
    physically removed ids AND still-resident tombstoned ids never appear
    in results."""
    li, deleted, ds = churned
    assert li.resident_dead > 0  # the mask path is actually exercised
    snap = li.snapshot()
    assert snap.tombstones is not None
    for nprobe in (None, 2):
        ids, _ = search(snap, ds.queries, 10, width=64, backend=backend,
                        dtype=dtype, nprobe=nprobe)
        assert not (set(ids.ravel().tolist()) & deleted)


def test_tombstoned_never_returned_merged(churned):
    """Merged-topology masking (incl. the pallas fused gate falling back
    to the host epilogue when tombstones are present)."""
    _, _, ds = churned
    res = build_scalegann(ds.data, CFG, algo="vamana")
    topo = res.topology(ds.data)
    gt1 = exact_ground_truth(ds.data, ds.queries, 1)[:, 0]
    tomb = np.zeros(len(ds.data), bool)
    tomb[gt1] = True  # kill each query's true nearest: masking must act
    t = dataclasses.replace(topo, tombstones=tomb)
    for backend in ("numpy", "jax", "pallas"):
        for dtype in ("f32", "uint8"):
            ids, _ = search(t, ds.queries, 10, width=64, backend=backend,
                            dtype=dtype)
            assert not (set(ids.ravel().tolist())
                        & set(gt1.tolist())), (backend, dtype)


def test_consolidate_goes_physical(churned):
    li, deleted, ds = churned
    before = li.resident_dead
    stats = li.consolidate()
    assert stats["removed"] == before
    assert li.resident_dead == 0
    snap = li.snapshot()
    assert snap.tombstones is None  # fast paths come back
    assert not (set(np.concatenate(snap.shard_ids).tolist()) & deleted)
    ids, _ = search(snap, ds.queries, 10, width=64, backend="numpy")
    gt = _live_gt(li, deleted, ds.queries)
    assert recall_at(ids, gt, 10) > 0.85
    assert not (set(ids.ravel().tolist()) & deleted)


def test_cow_generations_share_untouched_shards():
    """A mutation replaces only the mutated shard's arrays; earlier
    snapshots keep answering on theirs (what keeps identity-keyed device
    caches warm across epochs)."""
    ds = make_clustered(400, 8, n_queries=4, gt_k=5, seed=0)
    li = LiveIndex.from_build(
        build_scalegann(ds.data, CFG, algo="vamana"), ds.data, CFG,
        LiveConfig(backend="numpy"),
    )
    li.prepare("uint8")
    s0 = li.snapshot()
    stores0 = s0.shard_store()
    quant0 = s0.shard_quant("uint8")
    graphs0 = [g.copy() for g in s0.shard_graphs]
    # a tight cluster of inserts lands in exactly one shard
    target = 1
    pts = li._centroids[target][None, :] + np.random.default_rng(0).normal(
        0, 1e-3, (5, 8)).astype(np.float32)
    li.insert_batch(pts)
    s1 = li.snapshot()
    touched = [i for i in range(li.n_shards)
               if s1.shard_store()[i] is not stores0[i]]
    assert touched == [target]
    assert [i for i in range(li.n_shards)
            if s1.shard_quant("uint8")[i][0] is not quant0[i][0]] == [target]
    # the old snapshot's graphs were never mutated in place
    for g_old, g_now in zip(graphs0, s0.shard_graphs):
        np.testing.assert_array_equal(g_old, g_now)
    # deletes are pure-mask: no shard storage invalidated at all
    li.delete_batch(np.asarray([0, 1, 2]))
    s2 = li.snapshot()
    assert all(a is b for a, b in zip(s1.shard_store(), s2.shard_store()))
    assert s1.tombstones is None and s2.tombstones is not None


def test_shard_split_fires_and_serves():
    ds = make_clustered(300, 8, n_queries=8, gt_k=5, seed=1)
    li = LiveIndex.from_build(
        build_scalegann(ds.data, CFG, algo="vamana"), ds.data, CFG,
        LiveConfig(backend="numpy", split_max=120),
    )
    n0 = li.n_shards
    rng = np.random.default_rng(2)
    pts = (li._centroids[0][None, :]
           + rng.normal(0, 0.5, (150, 8))).astype(np.float32)
    gids = li.insert_batch(pts)
    assert li.n_shards > n0
    assert li._centroids.shape[0] == li.n_shards
    snap = li.snapshot()
    ids, _ = search(snap, pts[:10], 5, width=32, backend="numpy")
    hit = sum(g in set(row.tolist()) for g, row in zip(gids[:10], ids))
    assert hit >= 8  # inserted points are findable after the split


def test_epoch_swap_inflight_futures_resolve():
    """Mid-traffic generation swap: every future submitted before, during,
    and after the swap resolves (no rejected epochs), post-swap batches
    see the new generation's inserts and never a tombstoned id."""
    ds = make_clustered(400, 8, n_queries=1, gt_k=5, seed=4)
    li = LiveIndex.from_build(
        build_scalegann(ds.data, CFG, algo="vamana"), ds.data, CFG,
        LiveConfig(backend="numpy"),
    )
    rng = np.random.default_rng(5)
    new_pts = (ds.data[rng.choice(400, 12)]
               + rng.normal(0, 1e-3, (12, 8))).astype(np.float32)
    kill = rng.choice(400, 25, replace=False)

    async def main():
        cfg = ServingConfig(backend="numpy", k=5, width=32, max_batch=8,
                            max_wait_ms=1.0, pretrace=False)
        async with AnnServer(li.snapshot(), config=cfg) as srv:
            assert srv.topology_generation == 0
            # wave 1: in-flight before the swap
            futs = [srv.submit_nowait(ds.data[i]) for i in range(30)]
            await asyncio.sleep(0)  # let some batches flush
            gids = li.insert_batch(new_pts)
            li.delete_batch(kill)
            gen = srv.swap_topology(li.snapshot())
            assert gen == 1
            # wave 2: straddles the swap
            futs += [srv.submit_nowait(q) for q in new_pts]
            futs += [srv.submit_nowait(ds.data[i]) for i in kill[:10]]
            results = await asyncio.gather(*futs)
            assert len(results) == len(futs)  # nothing rejected or hung
            dead = set(int(i) for i in kill)
            for q, r in zip(new_pts, results[30:30 + len(new_pts)]):
                assert r.ids.shape == (5,)
            # post-swap answers never contain a tombstoned id
            for r in results[30:]:
                assert not (set(r.ids.tolist()) & dead)
            # a post-swap query for an inserted point finds it
            found = 0
            for g, q in zip(gids, new_pts):
                r = await srv.submit(q)
                found += int(g in set(r.ids.tolist()))
            assert found >= len(gids) - 1
            assert srv.stats.registry.gauge(
                "serving_topology_generation",
                "current served topology generation "
                "(bumped by swap_topology)").value == 1

    asyncio.run(main())


def test_swap_topology_validates():
    ds = make_clustered(100, 8, n_queries=1, gt_k=5, seed=0)
    li = LiveIndex.from_build(
        build_scalegann(ds.data, CFG, algo="vamana"), ds.data, CFG,
    )

    async def main():
        cfg = ServingConfig(backend="numpy", k=5, width=32, pretrace=False)
        async with AnnServer(li.snapshot(), config=cfg) as srv:
            other = make_clustered(50, 4, n_queries=1, gt_k=1, seed=1)
            wrong = LiveIndex.from_build(
                build_scalegann(other.data, CFG, algo="vamana"),
                other.data, CFG,
            )
            with pytest.raises(ValueError, match="dim"):
                srv.swap_topology(wrong.snapshot())
            assert srv.topology_generation == 0

    asyncio.run(main())


# ---- satellite regressions ----------------------------------------------


@pytest.mark.parametrize("build", [build_shard_index_vamana,
                                   build_shard_index_vamana_sequential])
@pytest.mark.parametrize("n", [0, 1])
def test_degenerate_shard_builds(build, n):
    """n ∈ {0, 1} shards (tombstone consolidation / shard splits produce
    them) build an edgeless graph instead of crashing on the empty-argmin
    medoid or the empty-batch np.resize."""
    vec = np.random.default_rng(0).normal(size=(n, 8)).astype(np.float32)
    idx = build(vec, CFG)
    assert idx.graph.shape == (n, min(CFG.degree, 1))
    assert (idx.graph == -1).all()
    assert idx.n_distance_computations == 0


def test_entry_points_exact_count():
    """entry_points(n) returns exactly min(n+1, n_vectors) unique seeds
    even when the medoid collides with a linspace sample (the old path
    silently shrank the seed set)."""
    g = np.full((100, 4), -1, np.int32)
    # medoid 0 collides with linspace's first sample
    gi = GlobalIndex(graph=g, medoid=0, n_vectors=100)
    for n in (1, 4, 16, 99, 150):
        seeds = gi.entry_points(n)
        assert len(seeds) == min(n + 1, 100), n
        assert len(np.unique(seeds)) == len(seeds)
        assert seeds.min() >= 0 and seeds.max() < 100
        assert 0 in seeds  # the medoid is always a seed
    # collision mid-range too
    gi = GlobalIndex(graph=g, medoid=33, n_vectors=100)
    seeds = gi.entry_points(99)  # linspace(0..99, 99) hits 33's region
    assert len(seeds) == 100 and 33 in seeds
    # determinism: two replicas agree
    np.testing.assert_array_equal(gi.entry_points(16), gi.entry_points(16))


def test_insert_empty_and_single_point_shard():
    """Inserts into a shard emptied by consolidation rebuild it from
    scratch through the degenerate-guarded offline builder."""
    ds = make_clustered(200, 8, n_queries=4, gt_k=5, seed=6)
    li = LiveIndex.from_build(
        build_scalegann(ds.data, CFG, algo="vamana"), ds.data, CFG,
        LiveConfig(backend="numpy"),
    )
    # wipe shard 0 entirely
    li.delete_batch(li._ids[0])
    li.consolidate()
    assert len(li._ids[0]) == 0
    # route one point straight at its centroid: lands in the empty shard
    p = li._centroids[0][None, :].astype(np.float32)
    gid = li.insert_batch(p)
    assert len(li._ids[0]) == 1
    ids, _ = search(li.snapshot(), p, 3, width=32, backend="numpy")
    assert int(gid[0]) in set(ids.ravel().tolist())
