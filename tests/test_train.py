"""Training substrate: optimizers, accumulation, compression, checkpoints."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs.base import get_arch, smoke_config
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train.optimizer import (adafactor, adamw, clip_by_global_norm,
                                   for_config, optimizer_state_bytes)
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_arch("tinyllama_1_1b"))
    m = build_model(cfg)
    return cfg, m


def make_batch(cfg, rng, b=4, s=32):
    toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    return {"tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, 1))}


def test_loss_decreases(setup, rng):
    cfg, m = setup
    opt = adamw()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, microbatch=2)
    state = init_train_state(m, opt, KEY, tcfg)
    step = jax.jit(make_train_step(m, opt, tcfg))
    batch = make_batch(cfg, rng)
    losses = []
    for _ in range(15):
        state, metrics = step(state, batch)  # same batch → must overfit
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2
    assert int(state.step) == 15


def test_grad_accumulation_equivalence(setup, rng):
    """microbatch=2 over B=4 must equal microbatch=0 (same mean grads)."""
    cfg, m = setup
    opt = adamw()
    batch = make_batch(cfg, rng)
    outs = []
    for mb in (0, 2):
        tcfg = TrainConfig(learning_rate=1e-2, microbatch=mb,
                           warmup_steps=0)
        state = init_train_state(m, opt, KEY, tcfg)
        step = jax.jit(make_train_step(m, opt, tcfg))
        state, _ = step(state, batch)
        outs.append(state.params)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        rtol=2e-3, atol=2e-4)


def test_adafactor_steps_and_memory(setup, rng):
    cfg, m = setup
    opt = adafactor()
    tcfg = TrainConfig(learning_rate=1e-3)
    state = init_train_state(m, opt, KEY, tcfg)
    step = jax.jit(make_train_step(m, opt, tcfg))
    batch = make_batch(cfg, rng)
    l0 = None
    for _ in range(10):
        state, metrics = step(state, batch)
        if l0 is None:
            l0 = float(metrics["loss"])
    assert float(metrics["loss"]) < l0
    # factored state is much smaller than AdamW's
    af = optimizer_state_bytes(m.spec, "adafactor")
    aw = optimizer_state_bytes(m.spec, "adamw")
    assert af < 0.2 * aw


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(10.0)
    _, norm2 = clip_by_global_norm(clipped, 1e9)
    assert float(norm2) == pytest.approx(1.0, rel=1e-5)


def test_compression_error_feedback(rng):
    """Error feedback: Σ of compressed updates converges to Σ of true
    gradients (bounded residual), unlike naive quantization."""
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 1e-3
    err = compression.init_error_state(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        cg, err = compression.compress_with_feedback(g, err)
        acc = acc + cg
    # accumulated compressed ≈ 50·g with residual ≤ one quantization step
    resid = np.abs(np.asarray(acc - 50 * g))
    q_step = float(jnp.max(jnp.abs(g + err))) / 127.0
    assert resid.max() <= q_step * 1.5


def test_compressed_training_still_converges(setup, rng):
    cfg, m = setup
    opt = adamw()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                       compress_grads=True)
    state = init_train_state(m, opt, KEY, tcfg)
    step = jax.jit(make_train_step(m, opt, tcfg))
    batch = make_batch(cfg, rng)
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1
    assert state.error_state is not None


def test_checkpoint_roundtrip_and_crash_consistency(setup, rng):
    cfg, m = setup
    opt = adamw()
    tcfg = TrainConfig()
    state = init_train_state(m, opt, KEY, tcfg)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, state.params, metadata={"arch": cfg.name})
        ckpt.save(d, 7, state.params)
        assert ckpt.latest_step(d) == 7
        restored, manifest = ckpt.restore(d, 3, state.params)
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert manifest["metadata"]["arch"] == cfg.name
        # crash consistency: tmp dirs are ignored by latest_step
        import os
        os.makedirs(os.path.join(d, "tmp_step_00000009"))
        assert ckpt.latest_step(d) == 7


def test_checkpoint_shape_mismatch_rejected(setup):
    cfg, m = setup
    params = m.init(KEY)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, params)
        other = build_model(
            dataclasses.replace(smoke_config(get_arch("tinyllama_1_1b")),
                                d_model=32, n_heads=2, n_kv_heads=2,
                                head_dim=16)
        ).init(KEY)
        with pytest.raises(ValueError):
            ckpt.restore(d, 0, other)


def test_data_pipeline_seek_determinism():
    from repro.data.pipeline import TokenPipeline, TokenPipelineConfig
    cfg = TokenPipelineConfig(vocab_size=100, seq_len=16, global_batch=4)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    p2 = TokenPipeline(cfg)
    p2.seek(3)
    b3 = p2.next_batch()
    assert np.array_equal(b3["tokens"], batches[3]["tokens"])
    # host sharding partitions the global batch
    ca = TokenPipelineConfig(vocab_size=100, seq_len=16, global_batch=4,
                             n_hosts=2, host_id=0)
    cb = dataclasses.replace(ca, host_id=1)
    a = TokenPipeline(ca).next_batch()
    b = TokenPipeline(cb).next_batch()
    full = TokenPipeline(cfg).next_batch()
    assert np.array_equal(np.concatenate([a["tokens"], b["tokens"]]),
                          full["tokens"])
