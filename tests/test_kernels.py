"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref
from repro.kernels.distance import pairwise_distance_pallas
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           flash_decode_pallas)
from repro.kernels.topk import bitonic_sort_pairs, knn_pallas


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# pairwise distance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,d", [(128, 128, 128), (256, 128, 256),
                                   (128, 384, 512)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_distance_kernel(rng, m, n, d, metric, dtype):
    q = _rand(rng, (m, d), dtype)
    x = _rand(rng, (n, d), dtype)
    got = pairwise_distance_pallas(q, x, metric=metric, interpret=True)
    want = ref.pairwise_distance(q, x, metric)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_pairwise_distance_padding_path(rng):
    """ops wrapper pads ragged shapes; values must be exact."""
    ops.set_pallas_mode("force_interpret")
    try:
        q = _rand(rng, (37, 33), jnp.float32)
        x = _rand(rng, (101, 33), jnp.float32)
        got = ops.pairwise_distance(q, x, "l2")
        want = ref.pairwise_l2(q, x)
        assert got.shape == (37, 101)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                        atol=1e-4)
    finally:
        ops.set_pallas_mode("auto")


# ---------------------------------------------------------------------------
# fused kNN
# ---------------------------------------------------------------------------


def test_bitonic_sort_matches_numpy(rng):
    v = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    i = jnp.asarray(rng.integers(0, 1000, (4, 64)).astype(np.int32))
    sv, si = bitonic_sort_pairs(v, i)
    order = np.argsort(np.asarray(v), axis=1, kind="stable")
    assert_allclose(np.asarray(sv), np.take_along_axis(np.asarray(v), order,
                                                       axis=1), rtol=1e-6)


@pytest.mark.parametrize("m,n,d,k", [(128, 256, 128, 8), (128, 128, 256, 32)])
def test_knn_kernel(rng, m, n, d, k):
    q = _rand(rng, (m, d), jnp.float32)
    x = _rand(rng, (n, d), jnp.float32)
    dist, idx = knn_pallas(q, x, k, interpret=True)
    want_d, want_i = ref.knn(q, x, k)
    assert_allclose(np.asarray(dist), np.asarray(want_d), rtol=1e-3,
                    atol=1e-3)
    # indices may differ on ties; check distance agreement instead
    got_rows = np.asarray(ref.pairwise_l2(q, x))[
        np.arange(m)[:, None], np.asarray(idx)
    ]
    assert_allclose(got_rows, np.asarray(want_d), rtol=1e-3, atol=1e-3)


def test_knn_kernel_masks_padding(rng):
    q = _rand(rng, (128, 128), jnp.float32)
    x = _rand(rng, (256, 128), jnp.float32)
    d, i = knn_pallas(q, x, 4, n_real=100, interpret=True)
    assert int(np.asarray(i).max()) < 100


# ---------------------------------------------------------------------------
# flash attention / decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,hkv", [(8, 8), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(rng, h, hkv, causal):
    b, s, dh = 2, 512, 64
    q = _rand(rng, (b, h, s, dh), jnp.float32)
    k = _rand(rng, (b, hkv, s, dh), jnp.float32)
    v = _rand(rng, (b, hkv, s, dh), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    want = ref.mha_attention(q, k, v, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_jnp_matches_ref(rng):
    b, h, hkv, s, dh = 2, 8, 4, 256, 32
    q = _rand(rng, (b, h, s, dh), jnp.float32)
    k = _rand(rng, (b, hkv, s, dh), jnp.float32)
    v = _rand(rng, (b, hkv, s, dh), jnp.float32)
    got = ops.flash_attention_jnp(q, k, v, q_chunk=64, kv_chunk=128)
    want = ref.mha_attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_jnp_cross_lengths(rng):
    """T > S (prefix cache): positions must offset correctly."""
    b, h, s, t, dh = 1, 4, 128, 256, 32
    q = _rand(rng, (b, h, s, dh), jnp.float32)
    k = _rand(rng, (b, h, t, dh), jnp.float32)
    v = _rand(rng, (b, h, t, dh), jnp.float32)
    got = ops.flash_attention_jnp(q, k, v, q_chunk=64, kv_chunk=64)
    want = ref.mha_attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("lens", [[512, 512], [100, 317]])
def test_flash_decode_kernel(rng, lens):
    b, h, hkv, t, dh = 2, 8, 4, 512, 64
    q = _rand(rng, (b, h, dh), jnp.float32)
    k = _rand(rng, (b, hkv, t, dh), jnp.float32)
    v = _rand(rng, (b, hkv, t, dh), jnp.float32)
    cl = jnp.asarray(lens, jnp.int32)
    got = flash_decode_pallas(q, k, v, cl, interpret=True)
    want = ref.decode_attention(q, k, v, cl)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
