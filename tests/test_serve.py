"""Serving engine: slot batching, greedy determinism, wave scheduling."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch, smoke_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = smoke_config(get_arch("tinyllama_1_1b"))
    model = build_model(cfg, max_seq_len=96)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(n, rng, max_new=6):
    return [
        Request(rid=i, prompt=rng.integers(0, 200, 5 + i, dtype=np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def test_all_requests_complete(served, rng):
    cfg, model, params = served
    eng = ServeEngine(model, params, ServeConfig(max_len=96, n_slots=2))
    reqs = _reqs(5, np.random.default_rng(0))
    eng.generate(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.output)


def test_greedy_is_deterministic(served):
    cfg, model, params = served
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params,
                          ServeConfig(max_len=96, n_slots=2,
                                      temperature=0.0))
        reqs = _reqs(3, np.random.default_rng(1))
        eng.generate(reqs)
        outs.append([tuple(r.output) for r in reqs])
    assert outs[0] == outs[1]


def test_greedy_independent_of_batch_composition(served):
    """A request's greedy output must not depend on which other requests
    share its wave when prompts have equal length (no padding effects)."""
    cfg, model, params = served
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 200, 8, dtype=np.int32) for _ in range(3)]

    def run(slots, subset):
        eng = ServeEngine(model, params,
                          ServeConfig(max_len=96, n_slots=slots))
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=5)
                for i in subset]
        eng.generate(reqs)
        return {r.rid: tuple(r.output) for r in reqs}

    together = run(3, [0, 1, 2])
    alone = {**run(1, [0]), **run(1, [1]), **run(1, [2])}
    assert together == alone


def test_eos_stops_generation(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, ServeConfig(max_len=96, n_slots=1))
    reqs = _reqs(1, np.random.default_rng(3), max_new=20)
    # force the greedy token to become EOS by probing one step first
    eng.generate(reqs)
    first = reqs[0].output[0]
    eng2 = ServeEngine(model, params,
                       ServeConfig(max_len=96, n_slots=1, eos_id=first))
    reqs2 = _reqs(1, np.random.default_rng(3), max_new=20)
    eng2.generate(reqs2)
    assert len(reqs2[0].output) == 1  # stopped at EOS immediately
