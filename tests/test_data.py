"""bigann formats, block streaming, synthetic data, prefetch reader."""

import os
import tempfile

import numpy as np
import pytest

from repro.data import formats
from repro.data.pipeline import PrefetchReader
from repro.data.synthetic import (exact_ground_truth, make_clustered,
                                  recall_at)


@pytest.mark.parametrize("ext,dtype", [(".fbin", np.float32),
                                       (".u8bin", np.uint8),
                                       (".i8bin", np.int8)])
def test_bin_roundtrip(rng, ext, dtype):
    data = (rng.normal(size=(100, 16)) * 50).astype(dtype)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x" + ext)
        formats.write_bin(path, data)
        assert formats.read_bin_header(path) == (100, 16)
        back = formats.read_bin(path)
        assert np.array_equal(np.asarray(back), data)
        back2 = formats.read_bin(path, mmap=False)
        assert np.array_equal(back2, data)


def test_block_iteration(rng):
    data = rng.normal(size=(100, 8)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.fbin")
        formats.write_bin(path, data)
        blocks = list(formats.iter_bin_blocks(path, 32))
        assert [len(b) for b in blocks] == [32, 32, 32, 4]
        assert np.array_equal(np.concatenate(blocks), data)


def test_append_rows(rng):
    a = rng.normal(size=(10, 4)).astype(np.float32)
    b = rng.normal(size=(5, 4)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.fbin")
        formats.append_rows(path, a)
        formats.append_rows(path, b)
        back = np.asarray(formats.read_bin(path))
        assert back.shape == (15, 4)
        assert np.array_equal(back, np.concatenate([a, b]))


def test_ids_manifest(rng):
    ids = rng.integers(0, 1_000_000, 50).astype(np.int64)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ids.ibin")
        formats.write_ids(path, ids)
        assert np.array_equal(formats.read_ids(path), ids.astype(np.int32))


def test_synthetic_gt_is_exact():
    ds = make_clustered(500, 16, n_queries=10, seed=0)
    # brute force check for one query
    q = np.asarray(ds.queries[0], np.float32)
    d = ((np.asarray(ds.data, np.float32) - q) ** 2).sum(1)
    want = np.argsort(d)[:10]
    assert set(want) == set(ds.gt[0])


def test_recall_metric():
    gt = np.asarray([[1, 2, 3]])
    assert recall_at(np.asarray([[1, 2, 3]]), gt, 3) == 1.0
    assert recall_at(np.asarray([[1, 9, 8]]), gt, 3) == pytest.approx(1 / 3)


def test_prefetch_reader_order(rng):
    data = rng.normal(size=(1000, 4)).astype(np.float32)
    blocks = list(PrefetchReader(data, 128))
    assert np.array_equal(np.concatenate(blocks), data)


def test_uint8_dataset_path():
    ds = make_clustered(300, 8, n_queries=5, dtype="uint8", seed=1)
    assert ds.data.dtype == np.uint8
    assert ds.gt.shape == (5, 10)
