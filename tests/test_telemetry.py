"""The telemetry subsystem's contracts: byte-stable span trees under a
fake clock, a truly free no-op recorder on the serving hot path, the
Prometheus exposition round-trip, and the two traced end-to-end flows
(serving request decomposition, fleet preemption lifecycle) the smoke
benches are CI-guarded with."""

import asyncio
import gc
import json
import sys
import time

import numpy as np
import pytest

from repro.configs.base import IndexConfig
from repro.core.scheduler import RuntimeModel
from repro.data.synthetic import make_clustered
from repro.fleet import PreemptionInjector, build_scalegann_fleet
from repro.search import ShardTopology
from repro.serving import AnnServer, ServerStats, ServingConfig
from repro.telemetry import (NULL_TRACER, ManualClock, MetricsRegistry,
                             SignatureGuard, Tracer, check_fleet_trace,
                             check_serving_trace, collect_stages,
                             current_tracer, parse_prometheus, record_stage,
                             stage_active, use_tracer, validate_chrome_trace)

# ---------------------------------------------------------------------------
# span tracer: determinism, nesting, export schema
# ---------------------------------------------------------------------------


def _record_fixture_run(tracer: Tracer, clock: ManualClock) -> None:
    """One deterministic recording: nested spans on explicit and inherited
    tracks, an instant, a post-hoc complete, and an async lane."""
    with tracer.span("build.partition", track="build", n=1000):
        clock.advance(0.5)
    with tracer.span("fleet.shard_build", track="worker-0", shard=3):
        clock.advance(0.1)
        tracer.instant("fleet.preempt.notice", shard=3)  # inherits track
        with tracer.span("vamana.pass"):  # inherits worker-0
            t0 = tracer.now()
            clock.advance(0.2)
            tracer.complete("vamana.round", t0, tracer.now(), round=1)
        clock.advance(0.05)
    t0 = tracer.now()
    clock.advance(0.003)
    t1 = tracer.now()
    tracer.async_complete("serve.request", "req0", t0, t1,
                          cat="serving", track="requests")
    tracer.async_complete("serve.engine", "req0", t0, t1,
                          cat="serving", track="requests")


def test_span_tree_byte_stable_under_manual_clock():
    runs = []
    for _ in range(2):
        clock = ManualClock()
        tr = Tracer(clock, process="fixture")
        _record_fixture_run(tr, clock)
        runs.append(tr.to_json())
    assert runs[0] == runs[1]  # identical bytes, not just equal objects
    obj = json.loads(runs[0])
    assert validate_chrome_trace(obj) == []

    events = [e for e in obj["traceEvents"] if e["ph"] != "M"]
    by_name = {e["name"]: e for e in events}
    # nesting: the round span's parent is the pass span, whose parent is
    # the attempt span — and track inheritance put them all on worker-0
    tracks = {e["tid"]: e["args"]["name"]
              for e in obj["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    attempt = by_name["fleet.shard_build"]
    nested = by_name["vamana.pass"]
    rnd = by_name["vamana.round"]
    assert tracks[attempt["tid"]] == "worker-0"
    assert nested["tid"] == attempt["tid"] == rnd["tid"]
    assert nested["args"]["parent_id"] == attempt["args"]["span_id"]
    assert rnd["args"]["parent_id"] == nested["args"]["span_id"]
    assert by_name["fleet.preempt.notice"]["tid"] == attempt["tid"]
    # durations are µs of fake-clock time
    assert by_name["vamana.round"]["dur"] == pytest.approx(0.2e6)
    assert by_name["build.partition"]["dur"] == pytest.approx(0.5e6)


def test_span_error_annotated_not_swallowed():
    clock = ManualClock()
    tr = Tracer(clock)
    with pytest.raises(ValueError):
        with tr.span("build.shard", track="build"):
            raise ValueError("boom")
    (ev,) = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
    assert ev["args"]["error"] == "ValueError"


def test_tracer_event_cap_drops_never_blocks():
    clock = ManualClock()
    tr = Tracer(clock, max_events=3)
    for i in range(6):
        t0 = tr.now()
        clock.advance(0.001)
        tr.complete("x", t0, tr.now())
    obj = tr.to_chrome()
    assert len([e for e in obj["traceEvents"] if e["ph"] == "X"]) == 3
    assert obj["otherData"]["dropped"] == 3


def test_use_tracer_installs_and_restores():
    assert current_tracer() is NULL_TRACER
    tr = Tracer(ManualClock())
    with use_tracer(tr):
        assert current_tracer() is tr
    assert current_tracer() is NULL_TRACER


# ---------------------------------------------------------------------------
# the disabled recorder is free
# ---------------------------------------------------------------------------


def test_null_tracer_shares_singletons():
    s1 = NULL_TRACER.span("serve.request", track="x", a=1)
    s2 = NULL_TRACER.span()
    assert s1 is s2  # no per-call allocation even when called
    assert s1.set(a=2) is s1
    with s1:
        pass
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.to_chrome()["traceEvents"] == []


def _serving_hot_pattern(tr, n: int) -> None:
    """The exact gating idiom the serving worker uses: one branch, and the
    kwargs-building call is never reached when disabled."""
    for _ in range(n):
        if tr.enabled:
            tr.instant("serve.retrace_risk", track="jit",
                       backend="jax", batch=8)


def test_null_tracer_hot_path_zero_allocations():
    _serving_hot_pattern(NULL_TRACER, 10)  # warm code objects / freelists
    gc.collect()
    before = sys.getallocatedblocks()
    _serving_hot_pattern(NULL_TRACER, 10_000)
    after = sys.getallocatedblocks()
    # transient loop internals are freed before we read again; the gated
    # telemetry itself must leave nothing live
    assert after - before <= 2


def test_stage_accumulator_thread_local_and_gated():
    assert not stage_active()
    record_stage("search.rerank", 1.0)  # nobody listening: dropped
    with collect_stages() as stages:
        assert stage_active()
        record_stage("search.rerank", 0.25)
        record_stage("search.rerank", 0.5)
        record_stage("other", 1.5)
    assert stages == {"search.rerank": 0.75, "other": 1.5}
    assert not stage_active()


# ---------------------------------------------------------------------------
# metrics registry + Prometheus round-trip
# ---------------------------------------------------------------------------


def test_prometheus_round_trip_through_parser():
    reg = MetricsRegistry()
    reg.counter("requests_total", "served requests",
                outcome="completed").inc(7)
    reg.counter("requests_total", outcome="shed").inc()
    reg.gauge("queue_depth", "pending requests").set(3.5)
    h = reg.histogram("latency_seconds", "e2e latency",
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    # a label value that needs escaping must survive the trip
    reg.counter("errs_total", kind='bad "quote"\\path\n').inc(2)

    text = reg.to_prometheus()
    parsed = parse_prometheus(text)
    assert parsed[("requests_total",
                   frozenset({("outcome", "completed")}))] == 7
    assert parsed[("requests_total", frozenset({("outcome", "shed")}))] == 1
    assert parsed[("queue_depth", frozenset())] == 3.5
    assert parsed[("latency_seconds_count", frozenset())] == 5
    assert parsed[("latency_seconds_sum", frozenset())] == pytest.approx(
        5.605)
    # cumulative le buckets, +Inf last
    assert parsed[("latency_seconds_bucket",
                   frozenset({("le", "0.01")}))] == 1
    assert parsed[("latency_seconds_bucket",
                   frozenset({("le", "0.1")}))] == 3
    assert parsed[("latency_seconds_bucket", frozenset({("le", "1")}))] == 4
    assert parsed[("latency_seconds_bucket",
                   frozenset({("le", "+Inf")}))] == 5
    assert parsed[("errs_total",
                   frozenset({("kind", 'bad "quote"\\path\n')}))] == 2


def test_registry_modeling_errors_raise():
    reg = MetricsRegistry()
    reg.counter("a_total", x="1")
    with pytest.raises(ValueError):  # same name, different label set
        reg.counter("a_total", y="1")
    with pytest.raises(ValueError):  # same name, different kind
        reg.gauge("a_total", x="1")
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        reg.counter("a_total", x="1").inc(-1)


def test_histogram_reservoir_deterministic_quantiles():
    mk = lambda: MetricsRegistry().histogram(  # noqa: E731
        "h", buckets=(1.0,), reservoir=64)
    a, b = mk(), mk()
    for i in range(1000):
        v = float((i * 37) % 101)
        a.observe(v)
        b.observe(v)
    assert a.percentile(50) == b.percentile(50)  # seeded reservoir
    assert a.summary() == b.summary()
    assert a.count == 1000 and a.summary(2.0)["mean"] == pytest.approx(
        2.0 * a.total / a.count)


def test_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c_total", "help here", k="v").inc(3)
    reg.histogram("h_seconds").observe(0.2)
    snap = reg.snapshot()
    assert snap["c_total"]["type"] == "counter"
    assert snap["c_total"]["series"] == [
        {"labels": {"k": "v"}, "value": 3.0}]
    hs = snap["h_seconds"]["series"][0]
    assert hs["count"] == 1 and hs["sum"] == pytest.approx(0.2)
    assert hs["summary"]["p50"] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# signature guard (the mid-traffic-retrace metric)
# ---------------------------------------------------------------------------


def test_signature_guard_flags_only_post_warmup_novelty():
    g = SignatureGuard()
    g.warm(("jax", 8, 1, "f32"))
    assert g.observe(("jax", 8, 1, "f32")) == (False, False)  # pretraced
    assert g.observe(("jax", 16, 1, "f32")) == (True, False)  # pre-warm new
    g.finish_warmup()
    assert g.observe(("jax", 32, 1, "f32")) == (True, True)  # the bad case
    assert g.observe(("jax", 32, 1, "f32")) == (False, False)  # now known
    assert g.n_signatures == 3


# ---------------------------------------------------------------------------
# trace validation negatives
# ---------------------------------------------------------------------------


def test_validate_chrome_trace_catches_malformed_events():
    assert validate_chrome_trace([]) != []  # not an object
    assert validate_chrome_trace({}) != []  # no traceEvents
    bad = {"traceEvents": [
        {"name": "x", "ph": "Z", "pid": 1, "tid": 1, "ts": 0},
        {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": -1},
        {"name": "x", "ph": "e", "pid": 1, "tid": 1, "ts": 0, "id": "a",
         "cat": "c"},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("invalid ph" in e for e in errs)
    assert any("dur" in e for e in errs)
    assert any("no open 'b'" in e for e in errs)
    unbalanced = {"traceEvents": [
        {"name": "x", "ph": "b", "pid": 1, "tid": 1, "ts": 0, "id": "a",
         "cat": "c"},
    ]}
    assert any("unbalanced" in e for e in validate_chrome_trace(unbalanced))


# ---------------------------------------------------------------------------
# ServerStats feeds the registry
# ---------------------------------------------------------------------------


def test_server_stats_queue_engine_split_and_exposition():
    st = ServerStats()
    for ms in (10.0, 20.0, 30.0):
        st.record_completion(0.0, ms / 1e3, queue_wait_s=0.4 * ms / 1e3,
                             engine_s=0.5 * ms / 1e3)
    snap = st.snapshot()
    assert snap["queue_wait_ms"]["p50"] == pytest.approx(8.0)
    assert snap["engine_service_ms"]["p50"] == pytest.approx(10.0)
    assert snap["latency_ms"]["p50"] == pytest.approx(20.0)
    text = st.to_prometheus()
    parsed = parse_prometheus(text)
    assert parsed[("serving_requests_total",
                   frozenset({("outcome", "completed")}))] == 3
    assert parsed[("serving_queue_wait_seconds_count", frozenset())] == 3
    assert parsed[("serving_engine_service_seconds_sum",
                   frozenset())] == pytest.approx(0.03)


# ---------------------------------------------------------------------------
# traced end-to-end: serving request decomposition
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ring():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(40, 8)).astype(np.float32)
    g = np.stack([(np.arange(40) + s) % 40 for s in range(1, 6)],
                 axis=1).astype(np.int32)
    topo = ShardTopology(data=data,
                         shard_ids=[np.arange(40, dtype=np.int64)],
                         shard_graphs=[g])
    return data, topo


def test_traced_serving_decomposes_request_latency(ring):
    data, topo = ring
    tracer = Tracer(clock=time.monotonic)  # must match the server clock

    async def main():
        sc = ServingConfig(backend="numpy", k=5, width=64, max_batch=4,
                           max_wait_ms=2.0)
        async with AnnServer(topo, config=sc, tracer=tracer) as srv:
            futs = [srv.submit_nowait(data[i]) for i in range(12)]
            for f in futs:
                await f

    asyncio.run(main())
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    chk = check_serving_trace(obj)
    assert chk["ok"], chk
    assert chk["n_requests"] == 12
    assert chk["min_coverage_seen"] >= 0.95


def test_traced_serving_counts_post_warm_signatures(ring):
    """With pretrace disabled, the first engine-call signature is by
    definition first seen after warm-up — the guard must count it."""
    data, topo = ring
    tracer = Tracer(clock=time.monotonic)

    async def main():
        sc = ServingConfig(backend="numpy", k=5, width=64, max_batch=4,
                           max_wait_ms=2.0, pretrace=False)
        async with AnnServer(topo, config=sc, tracer=tracer) as srv:
            await srv.submit_nowait(data[0])
            snap = srv.stats.registry.snapshot()
        series = snap["serving_post_warm_signatures_total"]["series"]
        assert series[0]["value"] >= 1

    asyncio.run(main())
    names = {e["name"] for e in tracer.to_chrome()["traceEvents"]}
    assert "serve.retrace_risk" in names


# ---------------------------------------------------------------------------
# traced end-to-end: fleet preemption lifecycle
# ---------------------------------------------------------------------------


def test_traced_fleet_shows_preemption_lifecycle():
    ds = make_clustered(600, 16, n_queries=8, seed=1)
    cfg = IndexConfig(n_clusters=4, degree=8, build_degree=16,
                      block_size=512)
    tracer = Tracer()
    out = build_scalegann_fleet(
        ds.data, cfg, n_workers=2, backend="numpy",
        # kill at round 2: round 1's checkpoint exists, so the retry
        # resumes instead of restarting (a round-1 kill has no checkpoint)
        injector=PreemptionInjector(kill_shard_at={0: 2}),
        runtime_model=RuntimeModel(seconds_per_vector=1e-4),
        tracer=tracer,
    )
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    chk = check_fleet_trace(obj)
    assert chk["ok"], chk
    assert chk["n_kills"] >= 1 and chk["n_resumes"] >= 1

    r = out.report
    assert r.metrics["fleet_preemptions_total"]["series"][0]["value"] == 1
    assert r.metrics["fleet_rounds_total"]["series"][0]["value"] == \
        r.rounds_completed
    # the per-shard timeline carries the lifecycle in order
    tl = {t.shard: t for t in r.shard_timelines}
    killed = tl[0]
    assert killed.attempts == 2
    kinds = [e[1] for e in killed.events]
    assert "kill" in kinds and "preempted" in kinds and "resume" in kinds
    assert kinds.index("kill") < kinds.index("resume")
    times = [e[0] for e in killed.events]
    assert times == sorted(times)
    for t in tl.values():
        assert all(e[3] == t.shard for e in t.events)
