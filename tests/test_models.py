"""Per-arch smoke tests (reduced configs) + mixer numerics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs.base import ARCH_IDS, get_arch, smoke_config
from repro.models import moe as moe_mod
from repro.models import rwkv6, ssm
from repro.models.model import build_model, padded_vocab

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, rng):
    s = S - cfg.n_patches if cfg.family == "vlm" else S
    toks = rng.integers(0, cfg.vocab_size, (B, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, 3200)).astype(np.float32)
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)
                       ).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_decode(arch, rng):
    """One forward/train step on CPU: output shapes + no NaNs (assignment
    requirement), plus a prefill→decode step."""
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, max_seq_len=S + 8)
    params = m.init(KEY)
    batch = make_batch(cfg, rng)

    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    logits, aux = m.forward_fn(params, batch)
    assert logits.shape == (B, batch["tokens"].shape[1],
                            padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all())

    lg, cache = jax.jit(lambda p, b: m.prefill_fn(p, b, S + 8))(params, batch)
    assert lg.shape == (B, padded_vocab(cfg.vocab_size))
    lg2, cache2 = jax.jit(m.decode_fn)(
        params, cache, batch["tokens"][:, 0], jnp.int32(batch["tokens"].shape[1])
    )
    assert bool(jnp.isfinite(lg2).all()), f"{arch} decode logits not finite"


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "rwkv6_1_6b",
                                  "jamba_v0_1_52b"])
def test_prefill_matches_forward(arch, rng):
    """Prefill last-position logits must equal forward's last position —
    the serving path and train path share weights and semantics."""
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, max_seq_len=S + 8)
    params = m.init(KEY)
    batch = make_batch(cfg, rng)
    logits_fwd, _ = m.forward_fn(params, batch)
    logits_pre, _ = m.prefill_fn(params, batch, S + 8)
    assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_fwd[:, -1], np.float32),
        rtol=0.12, atol=0.12,  # bf16 compute, different reduction orders
    )


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "rwkv6_1_6b"])
def test_decode_matches_forward_next_token(arch, rng):
    """Teacher-forced decode over k steps reproduces forward logits —
    validates the cache update (attention KV / recurrent state)."""
    cfg = smoke_config(get_arch(arch))
    m = build_model(cfg, max_seq_len=S + 8)
    params = m.init(KEY)
    batch = make_batch(cfg, rng)
    full_logits, _ = m.forward_fn(params, batch)  # [B, S, V]

    prefix = S - 4
    pre_batch = {"tokens": batch["tokens"][:, :prefix]}
    _, cache = m.prefill_fn(params, pre_batch, S + 8)
    for t in range(prefix, S):
        lg, cache = m.decode_fn(params, cache, batch["tokens"][:, t],
                                jnp.int32(t))
        want = np.asarray(full_logits[:, t], np.float32)
        got = np.asarray(lg, np.float32)
        # compare top-1 agreement (bf16 noise)
        assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.95


# ---------------------------------------------------------------------------
# Mixer numerics: chunked vs sequential
# ---------------------------------------------------------------------------


def test_mamba_chunked_matches_sequential(rng):
    Bm, T, dI, dS = 2, 32, 8, 4
    u = jnp.asarray(rng.normal(size=(Bm, T, dI)).astype(np.float32))
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(Bm, T, dI))
                                     .astype(np.float32)))
    bm = jnp.asarray(rng.normal(size=(Bm, T, dS)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(Bm, T, dS)).astype(np.float32))
    a_log = jnp.asarray(rng.normal(size=(dI, dS)).astype(np.float32)) * 0.3
    y_c, h_c = ssm._ssm_scan_chunked(u, dt, bm, cm, a_log, chunk=8)
    a = -jnp.exp(a_log)
    h = jnp.zeros((Bm, dI, dS))
    ys = []
    for t in range(T):
        decay = jnp.exp(dt[:, t][..., None] * a)
        inc = (dt[:, t] * u[:, t])[..., None] * bm[:, t][:, None, :]
        h = decay * h + inc
        ys.append(jnp.einsum("bds,bs->bd", h, cm[:, t]))
    y_s = jnp.stack(ys, axis=1)
    assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(h_c), np.asarray(h), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_wkv6_chunked_matches_sequential(rng, chunk):
    Bm, H, T, dh = 2, 3, 32, 8
    r, k, v = (jnp.asarray(rng.normal(size=(Bm, H, T, dh)).astype(np.float32))
               for _ in range(3))
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(Bm, H, T, dh))
                                .astype(np.float32)))
    u_b = jnp.asarray(rng.normal(size=(H, dh)).astype(np.float32))
    y_c, s_c = rwkv6.wkv6(r, k, v, logw, u_b, chunk=chunk)
    S_state = jnp.zeros((Bm, H, dh, dh))
    ys = []
    for t in range(T):
        kt, vt, rt = k[:, :, t], v[:, :, t], r[:, :, t]
        wt = jnp.exp(logw[:, :, t])
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        ys.append(jnp.einsum("bhk,bhkv->bhv", rt,
                             S_state + u_b[None, :, :, None] * kv))
        S_state = wt[..., None] * S_state + kv
    y_s = jnp.stack(ys, axis=2)
    assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(s_c), np.asarray(S_state), rtol=2e-4,
                    atol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------


def test_moe_capacity_and_combine(rng):
    dims = moe_mod.MoEDims(d_model=16, d_ff=32, n_experts=4, top_k=2,
                           capacity_factor=1.25)
    from repro.common.params import init_params
    p = init_params(moe_mod.moe_p(dims), KEY)
    x = jnp.asarray(rng.normal(size=(3, 8, 16)).astype(np.float32))
    out, aux = moe_mod.moe_forward(x, p, dims)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert 0.0 <= float(aux["dropped_fraction"]) <= 1.0
    assert float(aux["load_balance"]) >= 0.99  # ≥ 1 at optimum by design


def test_moe_capacity_drops_overflow(rng):
    """capacity_factor ≪ 1 forces drops; dropped_fraction must reflect it."""
    dims = moe_mod.MoEDims(d_model=8, d_ff=16, n_experts=2, top_k=1,
                           capacity_factor=0.25)
    from repro.common.params import init_params
    p = init_params(moe_mod.moe_p(dims), KEY)
    x = jnp.asarray(rng.normal(size=(1, 64, 8)).astype(np.float32))
    _, aux = moe_mod.moe_forward(x, p, dims)
    assert float(aux["dropped_fraction"]) > 0.4


def test_moe_expert_parallel_equivalence(rng):
    """One-token-per-expert sanity: output equals running that expert's MLP
    directly (capacity path exact)."""
    dims = moe_mod.MoEDims(d_model=8, d_ff=16, n_experts=2, top_k=1,
                           capacity_factor=4.0)
    from repro.common.params import init_params
    p = init_params(moe_mod.moe_p(dims), KEY)
    x = jnp.asarray(rng.normal(size=(1, 4, 8)).astype(np.float32))
    out, _ = moe_mod.moe_forward(x, p, dims)
    logits = np.asarray(x.reshape(-1, 8) @ np.asarray(p["router"]))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    e_sel = np.asarray(jnp.argmax(probs, -1))
    xt = np.asarray(x.reshape(-1, 8))
    for t in range(4):
        e = int(e_sel[t])
        g = xt[t] @ np.asarray(p["w_gate"][e])
        u = xt[t] @ np.asarray(p["w_up"][e])
        h = (g / (1 + np.exp(-g))) * u
        want = h @ np.asarray(p["w_down"][e])
        got = np.asarray(out.reshape(-1, 8))[t]
        assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_full_configs():
    """Full-config parameter counts match the public figures (±15%)."""
    expected = {
        "phi3_medium_14b": 14e9,
        "tinyllama_1_1b": 1.1e9,
        "phi3_mini_3_8b": 3.8e9,
        "granite_3_2b": 2.5e9,
        "kimi_k2_1t_a32b": 1.0e12,
        "arctic_480b": 480e9,
        "internvl2_76b": 70e9,   # LM backbone only (ViT is the stub)
        "jamba_v0_1_52b": 52e9,
        "rwkv6_1_6b": 1.6e9,
    }
    for arch, want in expected.items():
        m = build_model(get_arch(arch))
        got = m.n_params
        assert abs(got - want) / want < 0.25, (
            f"{arch}: {got/1e9:.1f}B vs expected {want/1e9:.1f}B"
        )


def test_moe_active_params():
    m = build_model(get_arch("kimi_k2_1t_a32b"))
    active = m.n_active_params()
    assert active < 0.1 * m.n_params  # 8/384 experts + attention
    assert 20e9 < active < 60e9  # "a32b"
